"""Device-nonideality subsystem: models, Monte-Carlo engine parity,
fault-aware planning, and end-to-end fault injection.

The engine contract under test: (a) the vectorised Monte-Carlo NF
engine must match the per-sample oracle (no batching artefacts), (b)
fault maps live in physical coordinates and the same map must produce
consistent results through the circuit solver, the Eq-17 evaluator and
the deployment-code injector, (c) fault-aware MDM must beat plain MDM
under known stuck-at-OFF faults.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import manhattan
from repro.core.bitslice import bitslice
from repro.core.mdm import MODES, placed_masks, plan_from_bits
from repro.core.tiling import CrossbarSpec
from repro.nonideal import (
    STUCK_OFF,
    STUCK_ON,
    NonidealModel,
    apply_to_conductances,
    conductances_from_masks,
    mc_nf,
    mc_nf_oracle,
    nonideal_magnitude,
    nonideal_weights,
    sample_cell_state,
    sample_stuck,
)

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)


def rand_masks(key, t=3, j=16, k=16, p=0.25):
    return (jax.random.uniform(key, (t, j, k)) < p).astype(jnp.float32)


# ------------------------------ device models -----------------------------

def test_sample_stuck_rates_and_exclusivity():
    key = jax.random.PRNGKey(0)
    s = np.asarray(sample_stuck(key, (200, 200), 0.1, 0.05))
    assert set(np.unique(s)) <= {0, STUCK_OFF, STUCK_ON}
    assert abs((s == STUCK_OFF).mean() - 0.1) < 0.01
    assert abs((s == STUCK_ON).mean() - 0.05) < 0.01


def test_sample_cell_state_key_discipline():
    """Enabling one term must not reshuffle another's draws (fixed
    fold_in tags), and identical keys reproduce identical samples."""
    key = jax.random.PRNGKey(3)
    shape = (4, 16, 16)
    a = sample_cell_state(key, shape,
                          NonidealModel(p_stuck_off=0.1,
                                        sigma_program=0.2))
    b = sample_cell_state(key, shape,
                          NonidealModel(p_stuck_off=0.1,
                                        sigma_program=0.2,
                                        sigma_read=0.1))
    np.testing.assert_array_equal(np.asarray(a.stuck), np.asarray(b.stuck))
    np.testing.assert_array_equal(np.asarray(a.gamma), np.asarray(b.gamma))
    c = sample_cell_state(key, shape,
                          NonidealModel(p_stuck_off=0.1,
                                        sigma_program=0.2))
    np.testing.assert_array_equal(np.asarray(a.gamma), np.asarray(c.gamma))


def test_apply_to_conductances_semantics():
    key = jax.random.PRNGKey(1)
    masks = rand_masks(key, t=2)
    g_on, g_off = 1.0 / SPEC.r_on, 1.0 / SPEC.r_off

    # Ideal model: identity on the clean conductances.
    ideal = sample_cell_state(key, masks.shape, NonidealModel())
    g = np.asarray(apply_to_conductances(masks, ideal, SPEC,
                                         NonidealModel()))
    np.testing.assert_allclose(
        g, np.asarray(conductances_from_masks(masks, SPEC)), rtol=1e-7)

    # Stuck cells pin to the rail conductances exactly, overriding
    # variation; drift scales healthy ON cells only.
    model = NonidealModel(p_stuck_off=0.2, p_stuck_on=0.2,
                          sigma_program=0.3, drift_nu=0.1, drift_time=10.)
    s = sample_cell_state(key, masks.shape, model)
    g = np.asarray(apply_to_conductances(masks, s, SPEC, model))
    stuck = np.asarray(s.stuck)
    np.testing.assert_allclose(g[stuck == STUCK_ON], g_on, rtol=1e-7)
    np.testing.assert_allclose(g[stuck == STUCK_OFF], g_off, rtol=1e-7)
    on_healthy = (np.asarray(masks) > 0) & (stuck == 0)
    expect = (g_on * model.drift_factor
              * np.asarray(s.gamma)[on_healthy])
    np.testing.assert_allclose(g[on_healthy], expect, rtol=1e-6)
    assert (g >= 0).all()


# --------------------------- Monte-Carlo engine ---------------------------

@pytest.mark.parametrize("model", [
    NonidealModel(p_stuck_off=0.05, p_stuck_on=0.01),
    NonidealModel(sigma_program=0.15, sigma_read=0.02),
    NonidealModel(p_stuck_off=0.03, sigma_program=0.1, sigma_read=0.01,
                  drift_nu=0.05, drift_time=100.0),
])
def test_mc_engine_matches_per_sample_oracle(model):
    """The fused (samples x tiles)-batched solve must reproduce the
    explicit per-sample loop: same PRNG draws, same currents to solver
    tolerance."""
    masks = rand_masks(jax.random.PRNGKey(2))
    key = jax.random.PRNGKey(7)
    a = mc_nf(masks, SPEC, model, 3, key, precision="f64")
    b = mc_nf_oracle(masks, SPEC, model, 3, key, precision="f64")
    np.testing.assert_allclose(np.asarray(a.nf_total), b.nf_total,
                               rtol=1e-9)
    np.testing.assert_allclose(np.asarray(a.weighted_err), b.weighted_err,
                               rtol=1e-9)
    assert int(a.unconverged) == 0


def test_mc_engine_sharded_matches_oracle():
    from repro.distributed.solver_shard import tile_sharding_ctx

    masks = rand_masks(jax.random.PRNGKey(4))
    model = NonidealModel(p_stuck_off=0.05, sigma_program=0.1)
    key = jax.random.PRNGKey(8)
    a = mc_nf(masks, SPEC, model, 4, key, precision="f64",
              ctx=tile_sharding_ctx())
    b = mc_nf_oracle(masks, SPEC, model, 4, key, precision="f64")
    np.testing.assert_allclose(np.asarray(a.nf_total), b.nf_total,
                               rtol=1e-9)
    assert int(a.unconverged) == 0


def test_mc_ideal_model_is_degenerate():
    """Zero nonideality: every sample reproduces the clean solve."""
    from repro.crossbar.batched import measured_nf_batched

    masks = rand_masks(jax.random.PRNGKey(5))
    res = mc_nf(masks, SPEC, NonidealModel(), 3, jax.random.PRNGKey(0),
                precision="f64")
    nf = np.asarray(res.nf_total)
    assert float(np.std(nf, axis=0).max()) == 0.0
    clean = measured_nf_batched(masks, SPEC)
    # rtol floor: conductances_from_masks stores g in f32 (device
    # conductances are not known to 1e-8 anyway); the mask path builds
    # g in f64.
    np.testing.assert_allclose(nf[0], np.asarray(clean.nf_total),
                               rtol=1e-6)


def test_mc_fixed_stuck_map_shared_across_samples():
    masks = rand_masks(jax.random.PRNGKey(6))
    stuck = sample_stuck(jax.random.PRNGKey(1), masks.shape, 0.1, 0.0)
    model = NonidealModel(p_stuck_off=0.5)  # rate ignored: map is pinned
    a = mc_nf(masks, SPEC, model, 2, jax.random.PRNGKey(0), stuck=stuck,
              precision="f64")
    b = mc_nf_oracle(masks, SPEC, model, 2, jax.random.PRNGKey(0),
                     stuck=stuck, precision="f64")
    np.testing.assert_allclose(np.asarray(a.nf_total), b.nf_total,
                               rtol=1e-9)
    # no variation terms -> the fixed map makes samples identical
    assert float(np.std(np.asarray(a.nf_total), axis=0).max()) == 0.0


@pytest.mark.slow
def test_mc_engine_paper_scale_tiles():
    """64x64 paper-geometry ensemble through the sharded engine."""
    from repro.distributed.solver_shard import tile_sharding_ctx

    spec = CrossbarSpec(rows=64, cols=64, n_bits=8)
    masks = (jax.random.uniform(jax.random.PRNGKey(0), (8, 64, 64))
             < 0.2).astype(jnp.float32)
    model = NonidealModel(p_stuck_off=0.02, sigma_program=0.1)
    res = mc_nf(masks, spec, model, 8, jax.random.PRNGKey(1),
                precision="mixed", ctx=tile_sharding_ctx())
    assert np.asarray(res.nf_total).shape == (8, 8)
    assert int(res.unconverged) == 0
    assert float(np.std(np.asarray(res.nf_total), axis=0).min()) > 0


# --------------------------- fault-aware planning -------------------------

def test_fault_aware_order_reduces_to_plain_without_faults():
    for seed in (0, 3, 9):
        m = rand_masks(jax.random.PRNGKey(seed), t=1)[0]
        plain = manhattan.optimal_row_order(m)
        aware = manhattan.fault_aware_row_order(
            m, jnp.zeros(m.shape, jnp.int8), SPEC.nf_unit)
        np.testing.assert_array_equal(np.asarray(plain),
                                      np.asarray(aware))


def test_fault_aware_order_is_permutation_and_steers():
    m = rand_masks(jax.random.PRNGKey(1), t=1)[0]
    dens = np.asarray(manhattan.row_counts(m))
    # Physical row 0 heavily stuck-OFF: the densest row must not land
    # there (it goes to the cheapest healthy position instead).
    stuck = jnp.zeros(m.shape, jnp.int8).at[0, :].set(STUCK_OFF)
    perm = np.asarray(manhattan.fault_aware_row_order(m, stuck,
                                                      SPEC.nf_unit))
    assert sorted(perm.tolist()) == list(range(m.shape[0]))
    assert dens[perm[0]] == dens.min()   # sparsest row absorbs the faults
    assert dens[perm[1]] == dens.max()   # densest takes the next position


def test_plan_population_fault_maps_matches_rowwise():
    masks = rand_masks(jax.random.PRNGKey(2), t=4)
    stuck = sample_stuck(jax.random.PRNGKey(3), masks.shape, 0.1, 0.05)
    from repro.core.mdm import plan_tile_population
    from repro.core.tiling import reverse_dataflow

    perm, pos, _, _, _, _ = plan_tile_population(masks, SPEC, "mdm",
                                                 stuck)
    placed = reverse_dataflow(masks)
    for t in range(masks.shape[0]):
        ref = manhattan.fault_aware_row_order(placed[t], stuck[t],
                                              SPEC.nf_unit)
        np.testing.assert_array_equal(np.asarray(perm[t]),
                                      np.asarray(ref))
        np.testing.assert_array_equal(
            np.asarray(pos[t])[np.asarray(perm[t])],
            np.arange(masks.shape[1]))


@pytest.mark.parametrize("mode", [m for m in MODES
                                  if m not in ("sort", "mdm")])
def test_fault_maps_noop_for_unsorted_modes(mode):
    from repro.core.mdm import plan_tile_population

    masks = rand_masks(jax.random.PRNGKey(4), t=2)
    stuck = sample_stuck(jax.random.PRNGKey(5), masks.shape, 0.2, 0.0)
    a = plan_tile_population(masks, SPEC, mode)
    b = plan_tile_population(masks, SPEC, mode, stuck)
    for fa, fb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fault_aware_mdm_beats_plain_mdm_measured(seed):
    """The acceptance check at tier-1 scale: under a known stuck-at-OFF
    map, fault-aware MDM must beat plain MDM on both the circuit-
    measured NF and the significance-weighted error distributions."""
    w = jax.random.laplace(jax.random.PRNGKey(seed), (64, 8)) * 0.01
    sliced = bitslice(w, SPEC.n_bits)
    ti, tn = SPEC.grid(*w.shape)
    stuck = sample_stuck(jax.random.PRNGKey(100 + seed),
                         (ti, tn, SPEC.rows, SPEC.cols), 0.08, 0.0)
    model = NonidealModel(p_stuck_off=0.08)
    wgt = (2.0 ** -(1.0 + np.arange(SPEC.cols) % SPEC.n_bits))[::-1]
    out = {}
    for name, aware in (("mdm", False), ("aware", True)):
        plan = plan_from_bits(sliced.bits, sliced.scale, SPEC, "mdm",
                              stuck if aware else None)
        placed = placed_masks(sliced.bits, plan, SPEC)
        res = mc_nf(placed.reshape(ti * tn, SPEC.rows, SPEC.cols), SPEC,
                    model, 2, jax.random.PRNGKey(7),
                    stuck=stuck.reshape(ti * tn, SPEC.rows, SPEC.cols),
                    col_weights=wgt.copy(), precision="f64")
        out[name] = (float(np.mean(np.asarray(res.nf_total))),
                     float(np.mean(np.asarray(res.weighted_err))))
    assert out["aware"][0] < out["mdm"][0]
    assert out["aware"][1] < out["mdm"][1]


# ----------------------- evaluator / injection parity ---------------------

def test_nonideal_magnitude_reduces_to_noisy_magnitude():
    from repro.core.noise import noisy_magnitude

    w = jax.random.normal(jax.random.PRNGKey(0), (48, 6)) * 0.2
    sliced = bitslice(w, SPEC.n_bits)
    for mode in ("baseline", "mdm"):
        plan = plan_from_bits(sliced.bits, sliced.scale, SPEC, mode)
        a = noisy_magnitude(sliced.bits, sliced.scale, plan, SPEC, 2e-3)
        b = nonideal_magnitude(sliced.bits, sliced.scale, plan, SPEC,
                               2e-3)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6)


def test_stuck_codes_injection_matches_evaluator():
    """Stuck faults folded into the deployment codes must reproduce the
    Eq-17 evaluator through the production cim_mvm path."""
    from repro.deploy import package_deployment_host
    from repro.kernels.cim_mvm.ops import cim_mvm
    from repro.nonideal.inject import HostCells

    w = jax.random.normal(jax.random.PRNGKey(0), (48, 6)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 48))
    ti, tn = SPEC.grid(*w.shape)
    stuck = np.asarray(sample_stuck(
        jax.random.PRNGKey(3), (ti, tn, SPEC.rows, SPEC.cols),
        0.05, 0.02))
    for mode in ("baseline", "mdm"):
        wp, plan = nonideal_weights(w, SPEC, mode, eta=2e-3,
                                    stuck=jnp.asarray(stuck))
        dep = package_deployment_host(
            np.asarray(w, np.float32), SPEC, mode, 2e-3, plan,
            cells=HostCells(stuck=stuck, gamma=None))
        dep = jax.tree_util.tree_map(jnp.asarray, dep)
        y = cim_mvm(x, dep, impl="xla")
        ref = x @ wp
        err = float(jnp.max(jnp.abs(y - ref))
                    / jnp.max(jnp.abs(ref)))
        assert err < 1e-5, (mode, err)


def test_variation_gain_tracks_evaluator():
    """Per-weight gain folding is exact on the clean-magnitude term and
    O(eta * sigma) on the parasitic column moment — the serving path
    must track the exact evaluator within that budget."""
    from repro.deploy import package_deployment_host
    from repro.kernels.cim_mvm.ops import cim_mvm
    from repro.nonideal.inject import HostCells

    w = jax.random.normal(jax.random.PRNGKey(0), (48, 6)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 48))
    ti, tn = SPEC.grid(*w.shape)
    model = NonidealModel(sigma_program=0.1)
    gamma = np.asarray(jnp.exp(0.1 * jax.random.normal(
        jax.random.PRNGKey(4), (ti, tn, SPEC.rows, SPEC.cols))),
        np.float32)
    wp, plan = nonideal_weights(w, SPEC, "mdm", eta=2e-3,
                                gamma=jnp.asarray(gamma), model=model)
    dep = package_deployment_host(
        np.asarray(w, np.float32), SPEC, "mdm", 2e-3, plan,
        cells=HostCells(stuck=None, gamma=gamma), nonideal=model)
    assert dep.gain is not None
    dep = jax.tree_util.tree_map(jnp.asarray, dep)
    y = cim_mvm(x, dep, impl="xla")
    ref = x @ wp
    rel = float(jnp.max(jnp.abs(y - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 5e-3
    # interpret mode must refuse rather than silently drop the gain
    with pytest.raises(ValueError, match="gain"):
        cim_mvm(x, dep, impl="interpret")


# ----------------------------- deployment E2E -----------------------------

def _serve_cfg():
    from repro.configs.base import CimConfig, ModelConfig

    return ModelConfig(
        name="cim-nonideal-test", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, block_pattern=("attn",),
        remat="none", dtype="float32", attn_chunk=32,
        cim=CimConfig(enabled=True, mode="mdm", rows=16, cols=16,
                      n_bits=4))


def test_serve_engine_generates_under_injected_faults():
    from repro.deploy import PlanCache
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = _serve_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = NonidealModel(p_stuck_off=0.02, p_stuck_on=0.005,
                          sigma_program=0.05)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    with tempfile.TemporaryDirectory() as d:
        eng = ServeEngine(cfg, params, max_seq=64,
                          plan_cache=PlanCache(d), nonideal=model,
                          nonideal_seed=3)
        assert eng.deploy_report["nonideal"]
        assert eng.deploy_report["fault_aware"]
        assert eng.deploy_report["stuck_cells"] > 0
        out = np.asarray(eng.generate(prompts, 3))
        assert out.shape == (2, 3)
        # Same seed -> same fault map -> identical generation; the
        # fault-aware plans also hit the cache (keys include the map).
        eng2 = ServeEngine(cfg, params, max_seq=64,
                           plan_cache=PlanCache(d), nonideal=model,
                           nonideal_seed=3)
        assert eng2.deploy_report["cache_misses"] == 0
        np.testing.assert_array_equal(out,
                                      np.asarray(eng2.generate(prompts, 3)))
        # A different fault seed is a different deployment.
        eng3 = ServeEngine(cfg, params, max_seq=64,
                           plan_cache=PlanCache(d), nonideal=model,
                           nonideal_seed=4)
        assert eng3.deploy_report["cache_misses"] > 0


def test_deploy_fault_maps_change_plan_keys():
    from repro.deploy import plan_matrices

    mats = {"m": jax.random.normal(jax.random.PRNGKey(0), (48, 6)) * 0.2}
    ti, tn = SPEC.grid(48, 6)
    stuck = np.asarray(sample_stuck(jax.random.PRNGKey(1),
                                    (ti, tn, SPEC.rows, SPEC.cols),
                                    0.1, 0.0))
    with tempfile.TemporaryDirectory() as d:
        from repro.deploy import PlanCache

        cache = PlanCache(d)
        plan_matrices(mats, SPEC, "mdm", cache=cache)
        _, r = plan_matrices(mats, SPEC, "mdm", cache=cache,
                             fault_maps={"m": stuck})
        assert r["cache_misses"] == 1   # fault map entered the key
        _, r = plan_matrices(mats, SPEC, "mdm", cache=cache,
                             fault_maps={"m": stuck})
        assert r["cache_hits"] == 1 and r["manifest_hit"]
