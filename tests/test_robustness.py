"""Structural-fault resilience: line opens, correlated variation,
spare-line remapping, graceful degradation, the solver convergence
watchdog, per-read noise, and plan-cache corruption tolerance.

The contracts under test: (a) line-open faults act at line granularity
with the composition/PRNG discipline of the other nonideality terms,
and OPEN is stronger than STUCK_OFF; (b) the ``spare_line`` pipeline
steers dense logical lines off severed physical lines and reduces to
the faultless xchangr+mdm plan when no map is supplied; (c) when spare
capacity runs out the deployment is marked degraded and *served through
the digital fallback* rather than producing structurally wrong crossbar
output; (d) a non-converged or NaN solve can never masquerade as a good
NF number — the watchdog flags it, escalates, and reports honestly;
(e) per-read noise is keyed, per-deployment decorrelated, and
bit-identical to the noiseless path when no key is supplied; (f) a
truncated or corrupt plan-cache entry is a miss, not a crash.
"""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import manhattan
from repro.core.bitslice import bitslice
from repro.core.mdm import placed_masks, plan_from_bits
from repro.core.tiling import CrossbarSpec
from repro.mapping import named_pipelines
from repro.nonideal import (
    OPEN,
    STUCK_OFF,
    STUCK_ON,
    NonidealModel,
    apply_to_conductances,
    mc_nf,
    sample_cell_state,
    sample_corr_field,
    sample_line_open,
)
from repro.nonideal.models import CellSample, cell_values

SPEC = CrossbarSpec(rows=16, cols=16, n_bits=8)
_P = named_pipelines()


def rand_masks(key, t=3, j=16, k=16, p=0.25):
    return (jax.random.uniform(key, (t, j, k)) < p).astype(jnp.float32)


# --------------------------- line-open sampler ----------------------------

def test_sample_line_open_is_line_granular():
    """OPEN cells must decompose exactly into whole open wordlines and
    whole open bitlines (per tile), at the requested rates."""
    s = np.asarray(sample_line_open(jax.random.PRNGKey(0),
                                    (400, 32, 32), 0.1, 0.05))
    assert set(np.unique(s)) <= {0, OPEN}
    is_open = s == OPEN
    wl = is_open.all(axis=-1)          # (T, rows) fully-open wordlines
    bl = is_open.all(axis=-2)          # (T, cols) fully-open bitlines
    np.testing.assert_array_equal(
        is_open, wl[:, :, None] | bl[:, None, :])
    assert abs(wl.mean() - 0.1) < 0.02
    assert abs(bl.mean() - 0.05) < 0.015


def test_sample_line_open_subtag_independence():
    """Enabling bitline opens must not reshuffle the wordline draw
    (fixed sub-tags off the line-term key)."""
    key = jax.random.PRNGKey(1)
    a = np.asarray(sample_line_open(key, (100, 16, 16), 0.1, 0.0)) == OPEN
    b = np.asarray(sample_line_open(key, (100, 16, 16), 0.1, 0.4)) == OPEN
    # Every cell open in the wordline-only draw stays open, and the set
    # of fully-open wordlines is unchanged by the bitline term.
    assert b[a].all()
    np.testing.assert_array_equal(a.all(axis=-1), b.all(axis=-1))


def test_sample_cell_state_line_opens_override_stuck():
    key = jax.random.PRNGKey(2)
    shape = (50, 16, 16)
    model = NonidealModel(p_stuck_on=0.5, p_open_wordline=0.2,
                          sigma_program=0.1)
    s = sample_cell_state(key, shape, model)
    stuck = np.asarray(s.stuck)
    open_rows = (stuck == OPEN).all(axis=-1)
    assert open_rows.any()
    # No stuck code survives on an open line.
    assert (stuck[(stuck == OPEN)] == OPEN).all()
    # Composition: the non-open cells carry exactly the draws of the
    # opens-free model (fixed fold_in tags).
    base = sample_cell_state(key, shape, NonidealModel(
        p_stuck_on=0.5, sigma_program=0.1))
    keep = stuck != OPEN
    np.testing.assert_array_equal(stuck[keep],
                                  np.asarray(base.stuck)[keep])
    np.testing.assert_array_equal(np.asarray(s.gamma),
                                  np.asarray(base.gamma))


def test_open_cells_conduct_nothing():
    """OPEN beats STUCK_OFF: zero conductance — no HRS leakage, no read
    noise — and zero cell value in the Eq-17 evaluator."""
    key = jax.random.PRNGKey(3)
    masks = rand_masks(key, t=2)
    model = NonidealModel(p_open_wordline=0.3, p_open_bitline=0.2,
                          sigma_read=0.5, sigma_program=0.2)
    s = sample_cell_state(key, masks.shape, model)
    g = np.asarray(apply_to_conductances(masks, s, SPEC, model))
    stuck = np.asarray(s.stuck)
    assert (stuck == OPEN).any()
    assert (g[stuck == OPEN] == 0.0).all()
    # STUCK_OFF keeps the HRS leakage — strictly more than OPEN.
    off = CellSample(jnp.full(masks.shape, STUCK_OFF, jnp.int8),
                     jnp.ones(masks.shape, jnp.float32),
                     jnp.zeros(masks.shape, jnp.float32))
    g_off = np.asarray(apply_to_conductances(masks, off, SPEC,
                                             NonidealModel()))
    assert (g_off > 0).all()
    cv = np.asarray(cell_values(masks, s.stuck, s.gamma, model))
    assert (cv[stuck == OPEN] == 0.0).all()


# ------------------------- correlated variation ---------------------------

def test_corr_field_unit_marginal_and_smooth():
    f = np.asarray(sample_corr_field(jax.random.PRNGKey(4),
                                     (3000, 16, 16), 4.0))
    assert abs(f.mean()) < 0.02
    assert abs(f.var() - 1.0) < 0.05
    # Neighbouring cells are strongly correlated, distant ones much
    # less (Gaussian kernel, length 4): interior columns only, to stay
    # clear of the normalisation edge effects.
    near = (f[:, :, 4:-5] * f[:, :, 5:-4]).mean()
    far = (f[:, :, :4] * f[:, :, 12:]).mean()
    assert near > 0.9
    assert far < 0.5
    assert near - far > 0.3


def test_corr_variation_composes_with_iid_spread():
    key = jax.random.PRNGKey(5)
    shape = (200, 16, 16)
    a = sample_cell_state(key, shape, NonidealModel(
        p_stuck_off=0.1, sigma_program=0.2))
    b = sample_cell_state(key, shape, NonidealModel(
        p_stuck_off=0.1, sigma_program=0.2, sigma_corr=0.3))
    # Enabling the correlated term leaves the other draws untouched...
    np.testing.assert_array_equal(np.asarray(a.stuck),
                                  np.asarray(b.stuck))
    # ...and multiplies gamma by exactly exp(sigma_corr * field) with
    # the field drawn off the fixed _TAG_CORR sub-key.
    from repro.nonideal.models import _TAG_CORR

    z = np.log(np.asarray(b.gamma) / np.asarray(a.gamma)) / 0.3
    field = np.asarray(sample_corr_field(
        jax.random.fold_in(key, _TAG_CORR), shape, 4.0))
    np.testing.assert_allclose(z, field, atol=1e-4)


# ------------------------- spare-line remapping ---------------------------

def test_spare_line_orders_reduce_to_plain_without_faults():
    for seed in (0, 7):
        m = rand_masks(jax.random.PRNGKey(seed), t=1)[0]
        z = jnp.zeros(m.shape, jnp.int8)
        np.testing.assert_array_equal(
            np.asarray(manhattan.optimal_row_order(m)),
            np.asarray(manhattan.fault_aware_row_order(
                m, z, SPEC.nf_unit, open_penalty=4.0)))
        np.testing.assert_array_equal(
            np.asarray(manhattan.optimal_col_order(m)),
            np.asarray(manhattan.fault_aware_col_order(
                m, z, SPEC.nf_unit, open_penalty=4.0)))


def test_spare_line_col_order_steers_off_open_bitline():
    m = rand_masks(jax.random.PRNGKey(1), t=1)[0]
    cdens = np.asarray(m.sum(axis=0))
    stuck = jnp.zeros(m.shape, jnp.int8).at[:, 0].set(OPEN)
    perm = np.asarray(manhattan.fault_aware_col_order(
        m, stuck, SPEC.nf_unit, open_penalty=4.0))
    assert sorted(perm.tolist()) == list(range(m.shape[1]))
    # The severed bitline (physical column 0) hosts the sparsest
    # logical column; the densest takes the next position.
    assert cdens[perm[0]] == cdens.min()
    assert cdens[perm[1]] == cdens.max()


def test_spare_line_plan_reduces_to_xchangr_without_faults():
    w = jax.random.laplace(jax.random.PRNGKey(2), (64, 8)) * 0.01
    sliced = bitslice(w, SPEC.n_bits)
    a = plan_from_bits(sliced.bits, sliced.scale, SPEC, _P["spare_line"])
    b = plan_from_bits(sliced.bits, sliced.scale, SPEC, _P["xchangr"])
    np.testing.assert_array_equal(np.asarray(a.row_perm),
                                  np.asarray(b.row_perm))
    np.testing.assert_array_equal(np.asarray(a.col_perm),
                                  np.asarray(b.col_perm))


def test_spare_line_cache_token_carries_parameters():
    """The open_penalty surcharge is behavioural: it must enter the
    plan-cache key (a reparametrised strategy can never silently serve
    another's cached plan)."""
    from repro.mapping import SpareLineCols, SpareLineRows

    tok = _P["spare_line"].cache_token()
    assert tok.startswith("pipe:")
    hot = _P["spare_line"].replace(rows=SpareLineRows(open_penalty=9.0))
    assert hot.cache_token() != tok
    hot = _P["spare_line"].replace(cols=SpareLineCols(open_penalty=9.0))
    assert hot.cache_token() != tok


def test_spare_line_beats_fault_aware_under_line_opens():
    """Tier-1 version of the fault_line_open acceptance bar: under
    known open lines, row+column spare-line remapping must beat the
    row-only fault-aware sort (which cannot move columns) in the
    *accuracy currency* — the significance-weighted output error of the
    measured circuit, and the significance-weighted current severed
    lines silence.  Since the column steering became
    significance-weighted, raw NF / raw bits lost are no longer the
    gate: the steering deliberately sacrifices dense *low-order* planes
    (many cheap bits) to protect sparse high-order ones (few expensive
    bits), so the weighted metrics are what must win."""
    from repro.core.mdm import physical_column_significance
    from repro.nonideal.models import OPEN

    spec = CrossbarSpec(rows=32, cols=32, n_bits=8)
    w = jax.random.laplace(jax.random.PRNGKey(0), (64, 16)) * 0.01
    sliced = bitslice(w, spec.n_bits)
    ti, tn = spec.grid(*w.shape)
    T = ti * tn
    stuck = sample_line_open(jax.random.PRNGKey(3),
                             (ti, tn, spec.rows, spec.cols), 0.06, 0.06)
    model = NonidealModel(p_open_wordline=0.06, p_open_bitline=0.06)
    rho = spec.r_on / spec.r_off
    out = {}
    for name in ("fault_aware", "spare_line"):
        pipe = _P[name]
        plan = plan_from_bits(sliced.bits, sliced.scale, spec,
                              pipe, stuck)
        placed = placed_masks(sliced.bits, plan, spec)
        flat = placed.reshape(T, spec.rows, spec.cols)
        sflat = jnp.asarray(stuck).reshape(T, spec.rows, spec.cols)
        col_perm = (None if plan.col_perm is None
                    else jnp.reshape(plan.col_perm, (T, spec.cols)))
        cw = physical_column_significance(spec, pipe.reversed_dataflow,
                                          col_perm, T)
        res = mc_nf(flat, spec, model, 2, jax.random.PRNGKey(7),
                    stuck=sflat, col_weights=cw, precision="f64")
        # Significance-weighted severed current: every cell on an open
        # line loses its whole current (off-cells included, at the
        # r_on/r_off ratio), weighted by the hosted plane.
        cell_cur = jnp.where(flat > 0, 1.0, rho)
        wlost = float(jnp.sum(jnp.asarray(cw)[:, None, :] * cell_cur
                              * (sflat == OPEN)))
        out[name] = (float(np.mean(np.asarray(res.weighted_err))),
                     wlost)
    assert out["spare_line"][0] < out["fault_aware"][0]
    assert out["spare_line"][1] < out["fault_aware"][1]


# ------------------------- convergence watchdog ---------------------------

def test_watchdog_all_converged_on_standard_population():
    from repro.crossbar.batched import measured_nf_batched_checked

    masks = rand_masks(jax.random.PRNGKey(0), t=6)
    res, report = measured_nf_batched_checked(masks, SPEC,
                                              precision="mixed")
    assert report.all_converged
    assert report.escalations == 0
    assert int(report.n_failed) == 0
    assert np.isfinite(np.asarray(res.nf_total)).all()


def test_watchdog_flags_starved_budget_honestly():
    """A deliberately tiny iteration budget must be *reported*, never
    silently returned as a good NF."""
    from repro.crossbar.batched import measured_nf_batched_checked

    masks = rand_masks(jax.random.PRNGKey(1), t=4)
    res, report = measured_nf_batched_checked(
        masks, SPEC, maxiter=1, precision="f64", escalate=False)
    assert not report.all_converged
    assert int(report.n_failed) > 0
    assert report.escalations == 0


def test_watchdog_escalation_recovers_f32_tolerance_stall():
    """float32 CG stalls near its epsilon and cannot reach tol=1e-12;
    the ladder's f64 rerun must recover every tile and say so."""
    from repro.crossbar.batched import measured_nf_batched_checked

    masks = rand_masks(jax.random.PRNGKey(2), t=4)
    _, unescalated = measured_nf_batched_checked(
        masks, SPEC, precision="f32", escalate=False)
    assert not unescalated.all_converged
    res, report = measured_nf_batched_checked(masks, SPEC,
                                              precision="f32")
    assert report.all_converged
    assert report.escalations >= 1
    assert int(report.n_failed) == 0
    # The patched-in rerun matches the straight f64 answer.
    ref, _ = measured_nf_batched_checked(masks, SPEC, precision="f64")
    np.testing.assert_allclose(np.asarray(res.nf_total),
                               np.asarray(ref.nf_total), rtol=1e-9)


def test_watchdog_degenerate_tiles_no_nan_masquerade():
    """All-stuck-OFF, zero-drive and fully-severed (all-OPEN, zero
    conductance) tiles: wherever the report claims convergence the NF
    must be finite, and failures must be counted — never NaN passed
    off as converged."""
    from repro.crossbar.batched import measured_nf_conductances_checked

    g_on, g_off = 1.0 / SPEC.r_on, 1.0 / SPEC.r_off
    rng = np.random.default_rng(0)
    normal = np.where(rng.random((16, 16)) < 0.3, g_on, g_off)
    all_off = np.full((16, 16), g_off)     # every cell stuck at HRS
    severed = np.zeros((16, 16))           # every line open
    g = jnp.asarray(np.stack([normal, all_off, severed]), jnp.float32)
    res, report = measured_nf_conductances_checked(g, SPEC)
    conv = np.asarray(report.converged)
    nf = np.asarray(res.nf_total)
    assert conv.shape == (3,)
    assert conv[0] and conv[1]
    assert np.isfinite(nf[conv]).all()
    assert int(report.n_failed) == int((~conv).sum())


def test_watchdog_zero_drive_input_converges_finite():
    from repro.crossbar.batched import measured_nf_batched_checked

    masks = rand_masks(jax.random.PRNGKey(3), t=2)
    res, report = measured_nf_batched_checked(
        masks, SPEC, v_in=jnp.zeros((16,)))
    assert report.all_converged
    assert np.isfinite(np.asarray(res.nf_total)).all()


def test_watchdog_nan_conductance_reported_honestly():
    """A NaN tile can never converge; escalation runs, fails, and the
    report says so — without contaminating the healthy tiles."""
    from repro.crossbar.batched import measured_nf_conductances_checked

    masks = np.asarray(rand_masks(jax.random.PRNGKey(4), t=3))
    g = np.where(masks > 0, 1.0 / SPEC.r_on, 1.0 / SPEC.r_off)
    g[1, 3, 3] = np.nan
    res, report = measured_nf_conductances_checked(jnp.asarray(g), SPEC)
    conv = np.asarray(report.converged)
    np.testing.assert_array_equal(conv, [True, False, True])
    assert report.escalations >= 1
    assert int(report.n_failed) == 1
    assert np.isfinite(np.asarray(res.nf_total)[conv]).all()


def test_measured_nf_checked_single_tile_scalar_report():
    from repro.crossbar.solver import measured_nf_checked

    m = rand_masks(jax.random.PRNGKey(5), t=1)[0]
    res, report = measured_nf_checked(m, SPEC)
    assert np.asarray(report.converged).shape == ()
    assert bool(report.converged)
    assert np.asarray(res.nf_total).shape == ()


def test_mc_nf_surfaces_solver_report():
    masks = rand_masks(jax.random.PRNGKey(6))
    res = mc_nf(masks, SPEC, NonidealModel(p_stuck_off=0.05), 2,
                jax.random.PRNGKey(0), precision="mixed")
    assert res.report is not None
    assert res.report.all_converged
    assert int(res.unconverged) == int(res.report.n_failed)


# ------------------- graceful degradation + serving -----------------------

def test_open_bit_overlap_host_counts_programmed_bits():
    from repro.nonideal.inject import open_bit_overlap_host

    codes = np.array([[0b1010]], np.uint32)        # planes 0,2 are 1
    healthy = np.zeros((1, 1, 4), np.int8)
    assert open_bit_overlap_host(codes, healthy, 4) == 0
    on_one = healthy.copy()
    on_one[0, 0, 0] = OPEN                         # plane 0: bit is 1
    assert open_bit_overlap_host(codes, on_one, 4) == 1
    on_zero = healthy.copy()
    on_zero[0, 0, 1] = OPEN                        # plane 1: bit is 0
    assert open_bit_overlap_host(codes, on_zero, 4) == 0
    both = healthy.copy()
    both[0, 0, :] = OPEN                           # all planes severed
    assert open_bit_overlap_host(codes, both, 4) == 2


def test_cim_matmul_demotes_degraded_deployment():
    from repro.kernels.cim_mvm.ops import deploy
    from repro.models.model import _cim_matmul

    w = jax.random.normal(jax.random.PRNGKey(0), (32, 4)) * 0.2
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
    dep, _ = deploy(w, SPEC, "mdm")
    healthy = dataclasses.replace(dep, degraded=jnp.int32(0))
    broken = dataclasses.replace(dep, degraded=jnp.int32(7))
    np.testing.assert_array_equal(
        np.asarray(_cim_matmul(x, w, healthy)),
        np.asarray(_cim_matmul(x, w, dep)))
    np.testing.assert_allclose(np.asarray(_cim_matmul(x, w, broken)),
                               np.asarray(x @ w), rtol=1e-6)


def test_expert_mm_demotes_only_degraded_expert():
    from repro.kernels.cim_mvm.ops import cim_mvm, deploy
    from repro.models.moe import _expert_mm

    ws = [jax.random.normal(jax.random.PRNGKey(e), (32, 4)) * 0.2
          for e in range(2)]
    deps = []
    for e, we in enumerate(ws):
        d, _ = deploy(we, SPEC, "mdm")
        deps.append(dataclasses.replace(
            d, degraded=jnp.int32(5 if e == 0 else 0)))
    dep = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *deps)
    xe = jax.random.normal(jax.random.PRNGKey(9), (2, 3, 32))
    w = jnp.stack(ws)
    y = _expert_mm(xe, w, dep, 0)
    np.testing.assert_allclose(np.asarray(y[0]),
                               np.asarray(xe[0] @ ws[0]), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y[1]),
                               np.asarray(cim_mvm(xe[1], deps[1])),
                               rtol=1e-6)


def _serve_cfg(mode="spare_line"):
    from repro.configs.base import CimConfig, ModelConfig

    return ModelConfig(
        name="cim-robustness-test", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, block_pattern=("attn",),
        remat="none", dtype="float32", attn_chunk=32,
        cim=CimConfig(enabled=True, mode=mode, rows=16, cols=16,
                      n_bits=4))


def test_serve_engine_degrades_gracefully_under_heavy_opens():
    """Spares exhausted end-to-end: heavy line opens past what the
    spare-line remap can absorb must mark deployments degraded, report
    them, and still serve (digital fallback) — finite, deterministic
    generation, with per-read noise armed on the surviving crossbars."""
    from repro.deploy import PlanCache
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = _serve_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = NonidealModel(p_open_wordline=0.15, p_open_bitline=0.10,
                          sigma_read=0.03)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    with tempfile.TemporaryDirectory() as d:
        eng = ServeEngine(cfg, params, max_seq=64,
                          plan_cache=PlanCache(d), nonideal=model,
                          nonideal_seed=3)
        assert eng.deploy_report["n_degraded"] > 0
        for reason in eng.deploy_report["degraded"].values():
            assert "digital fallback" in reason
        out = np.asarray(eng.generate(prompts, 4))
        assert out.shape == (2, 4)
        assert (out >= 0).all() and (out < cfg.vocab_size).all()
        # Same seed => same fault map, same read-noise keys: the run
        # is reproducible across engines.
        eng2 = ServeEngine(cfg, params, max_seq=64,
                           plan_cache=PlanCache(d), nonideal=model,
                           nonideal_seed=3)
        np.testing.assert_array_equal(
            out, np.asarray(eng2.generate(prompts, 4)))


def test_serve_engine_no_opens_means_no_degradation():
    from repro.models.model import init_params
    from repro.serve import ServeEngine

    cfg = _serve_cfg(mode="mdm")
    params = init_params(cfg, jax.random.PRNGKey(0))
    model = NonidealModel(p_stuck_off=0.02, sigma_program=0.05)
    eng = ServeEngine(cfg, params, max_seq=64, nonideal=model,
                      nonideal_seed=3)
    assert eng.deploy_report["n_degraded"] == 0
    assert eng.deploy_report["degraded"] == {}


# ----------------------------- per-read noise -----------------------------

def _noisy_dep(sigma=0.05, tag=0):
    from repro.kernels.cim_mvm.ops import deploy

    w = jax.random.normal(jax.random.PRNGKey(0), (32, 4)) * 0.2
    dep, _ = deploy(w, SPEC, "mdm")
    return dataclasses.replace(dep, sigma_read=sigma,
                               noise_tag=jnp.int32(tag))


def test_read_noise_keyed_deterministic_and_decorrelated():
    from repro.kernels.cim_mvm.ops import cim_mvm

    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
    dep = _noisy_dep()
    clean = dataclasses.replace(dep, sigma_read=0.0, noise_tag=None)
    # No key: bit-identical to the noiseless deployment.
    np.testing.assert_array_equal(np.asarray(cim_mvm(x, dep)),
                                  np.asarray(cim_mvm(x, clean)))
    k = jax.random.PRNGKey(7)
    y1 = np.asarray(cim_mvm(x, dep, read_key=k))
    assert not np.array_equal(y1, np.asarray(cim_mvm(x, clean)))
    # Deterministic per key, fresh per key.
    np.testing.assert_array_equal(y1, np.asarray(
        cim_mvm(x, dep, read_key=k)))
    assert not np.array_equal(y1, np.asarray(
        cim_mvm(x, dep, read_key=jax.random.PRNGKey(8))))
    # The per-deployment tag decorrelates matrices under one shared key.
    other = dataclasses.replace(dep, noise_tag=jnp.int32(1))
    assert not np.array_equal(y1, np.asarray(
        cim_mvm(x, other, read_key=k)))
    # The perturbation is noise, not corruption.
    ref = np.asarray(cim_mvm(x, clean))
    assert float(np.max(np.abs(y1 - ref))) < 0.5 * float(
        np.max(np.abs(ref)) + 1e-9)


def test_read_noise_refused_outside_xla_path():
    from repro.kernels.cim_mvm.ops import cim_mvm

    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32))
    dep = _noisy_dep()
    with pytest.raises(ValueError, match="read noise"):
        cim_mvm(x, dep, read_key=jax.random.PRNGKey(0),
                impl="interpret")


# ------------------------ plan-cache robustness ---------------------------

def _plan_and_cache(tmpdir, pipe):
    from repro.deploy import PlanCache
    from repro.deploy.cache import plan_key, weight_fingerprint

    w = np.asarray(jax.random.laplace(jax.random.PRNGKey(0),
                                      (64, 8)) * 0.01, np.float32)
    sliced = bitslice(jnp.asarray(w), SPEC.n_bits)
    plan = plan_from_bits(sliced.bits, sliced.scale, SPEC, pipe)
    key = plan_key(weight_fingerprint(w), SPEC, pipe.cache_token())
    cache = PlanCache(tmpdir)
    cache.put(key, plan)
    return cache, key, plan


@pytest.mark.parametrize("pipe_name", ["mdm", "spare_line"])
def test_plan_cache_truncated_entry_is_miss(pipe_name):
    """fsynced writes are atomic, but a torn/truncated entry on disk
    (power loss, partial copy) must read as a miss — never a crash,
    never a garbage plan.  Covers both the legacy layout and the
    column-block (flags&2) layout."""
    with tempfile.TemporaryDirectory() as d:
        cache, key, plan = _plan_and_cache(d, _P[pipe_name])
        assert cache.get(key) is not None
        path = cache._path(key)
        with open(path, "rb") as f:
            buf = f.read()
        for corrupt in (buf[:-5], buf[:9], b"", buf + b"xx"):
            with open(path, "wb") as f:
                f.write(corrupt)
            misses = cache.stats.misses
            assert cache.get(key) is None
            assert cache.stats.misses == misses + 1
        # A fresh put repairs the entry.
        cache.put(key, plan)
        got = cache.get(key)
        np.testing.assert_array_equal(np.asarray(got.row_perm),
                                      np.asarray(plan.row_perm))


def test_plan_cache_corrupt_manifest_falls_back():
    import os

    with tempfile.TemporaryDirectory() as d:
        cache, key, plan = _plan_and_cache(d, _P["mdm"])
        keys = {"m": key}
        cache.put_manifest(keys, {"m": plan})
        assert cache.get_manifest(keys) is not None
        mdir = os.path.join(cache.root, "manifest")
        for root, _, files in os.walk(mdir):
            for name in files:
                p = os.path.join(root, name)
                with open(p, "rb") as f:
                    buf = f.read()
                with open(p, "wb") as f:
                    f.write(buf[: len(buf) // 2])
        assert cache.get_manifest(keys) is None
        # Per-entry probes still serve the plan.
        assert cache.get(key) is not None


# ------------------------ benchmark harness guard -------------------------

def test_bench_resolve_only_prefers_exact_name():
    """`--only fault_tolerance` must select exactly that benchmark even
    though fault_line_open shares its backing module (the nightly lines
    would otherwise double-run the sweep)."""
    from benchmarks.run import resolve_only

    assert [b.name for b in resolve_only("fault_tolerance")] == [
        "fault_tolerance"]
    assert [b.name for b in resolve_only("fault_line_open")] == [
        "fault_line_open"]
    # An exact name that doubles as a module name stays addressable on
    # its own; a pure module token still fans out to all its benches.
    assert [b.name for b in resolve_only("solver_throughput")] == [
        "solver_throughput"]
    assert [b.name for b in resolve_only("theorem1")] == [
        "theorem1_sparsity"]
    with pytest.raises(KeyError):
        resolve_only("no_such_bench")
