"""Per-arch smoke tests + decode/train consistency + sharding specs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.distributed.sharding import ShardingCtx, logical_spec
from repro.models import model as M

CTX = ShardingCtx()
KEY = jax.random.PRNGKey(0)

# Tier-1 smokes a representative pair (cheap dense + MoE/SWA); the full
# arch sweep runs in the nightly profile (scripts/test_nightly.sh).
TIER1_ARCHS = {"phi3-mini-3.8b", "mixtral-8x7b"}


def arch_grid(archs):
    return [a if a in TIER1_ARCHS else pytest.param(a, marks=pytest.mark.slow)
            for a in sorted(archs)]


def make_batch(cfg, B=2, S=32):
    if cfg.frontend:
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model)),
                "labels": jax.random.randint(KEY, (B, S), 0,
                                             cfg.vocab_size)}
    return {"tokens": jax.random.randint(KEY, (B, S + 1), 0,
                                         cfg.vocab_size)}


@pytest.mark.parametrize("arch", arch_grid(ARCHS))
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/loss + grad on CPU, shapes + finiteness."""
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg)
    loss, metrics = M.train_loss(params, cfg, CTX, batch)
    assert jnp.isfinite(loss), arch
    grads = jax.grad(lambda p: M.train_loss(p, cfg, CTX, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in
             jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", arch_grid(ARCHS))
def test_arch_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    st = M.init_decode_state(cfg, 2, 64)
    logits, st2, _ = M.apply_model(params, cfg, CTX,
                                   tokens=jnp.zeros((2, 1), jnp.int32),
                                   state=st, decode=True)
    assert logits.shape == (2, 1, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(st2["pos"]) == 1


@pytest.mark.parametrize("arch", arch_grid(
    ["phi3-mini-3.8b", "deepseek-coder-33b", "qwen2.5-32b", "hymba-1.5b",
     "xlstm-1.3b", "mixtral-8x7b"]))
def test_prefill_decode_matches_full_forward(arch):
    """Autoregressive invariant: prefill(S-1) + decode(1) == forward(S)."""
    cfg = get_config(arch, smoke=True)
    params = M.init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 16), 0,
                              cfg.vocab_size)
    full, _, _ = M.apply_model(params, cfg, CTX, tokens=toks)
    st = M.init_decode_state(cfg, 2, 64)
    _, st, _ = M.apply_model(params, cfg, CTX, tokens=toks[:, :15], state=st)
    last, _, _ = M.apply_model(params, cfg, CTX, tokens=toks[:, 15:16],
                               state=st, decode=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, 15]),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.slow
def test_sliding_window_ring_buffer():
    """Decoding past the window with a ring cache matches a full-cache
    run (mixtral SWA semantics: only the last `window` keys attend)."""
    cfg = get_config("mixtral-8x7b", smoke=True)  # window=32
    params = M.init_params(cfg, KEY)
    T = 48  # beyond the window
    toks = jax.random.randint(jax.random.PRNGKey(9), (1, T + 1), 0,
                              cfg.vocab_size)
    full, _, _ = M.apply_model(params, cfg, CTX, tokens=toks[:, :T])
    # ring cache is capped at window size
    st = M.init_decode_state(cfg, 1, T)
    # cache layout (R, B, C, Hkv, Dh): ring length capped at the window
    assert st["slot0_attn"]["k"].shape[2] == cfg.sliding_window
    for t in range(T):
        last, st, _ = M.apply_model(params, cfg, CTX,
                                    tokens=toks[:, t:t + 1], state=st,
                                    decode=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(full[:, T - 1]),
                               rtol=2e-2, atol=2e-2)


def test_loss_chunking_equivalence():
    cfg = get_config("phi3-mini-3.8b", smoke=True)
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg, B=2, S=32)
    l0, _ = M.train_loss(params, cfg, CTX, batch)
    l1, _ = M.train_loss(params, cfg.replace(loss_chunk=8), CTX, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)


def test_vocab_padding_masked():
    """hymba's vocab 32001 -> padded; padded logits must be ~-inf."""
    cfg = get_config("hymba-1.5b", smoke=True).replace(vocab_size=250)
    params = M.init_params(cfg, KEY)
    logits, _, _ = M.apply_model(params, cfg, CTX,
                                 tokens=jnp.zeros((1, 4), jnp.int32))
    assert logits.shape[-1] == cfg.padded_vocab == 256
    assert bool(jnp.all(logits[..., 250:] < -1e8))


def test_long_context_eligibility():
    eligible = {a for a in ARCHS
                if get_config(a).supports_long_context}
    assert eligible == {"mixtral-8x7b", "hymba-1.5b", "xlstm-1.3b"}


def test_partition_specs_structure():
    """Specs tree mirrors params tree; weights get 2-D sharding on a
    16x16 abstract mesh; awkward dims fall back to replication."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import make_abstract_mesh
    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    ctx = ShardingCtx(mesh=mesh)
    cfg = get_config("deepseek-coder-33b")  # 56 heads: not /16
    specs = M.param_partition_specs(cfg, ctx)
    params_abstract = __import__(
        "repro.models.schema", fromlist=["abstract_params"]
    ).abstract_params(cfg)
    assert jax.tree_util.tree_structure(specs) == \
        jax.tree_util.tree_structure(params_abstract)
    blk = specs["slot0_attn"]
    # wq: (L, D, 56, 128): heads dim not divisible -> head_dim takes model
    assert blk["wq"] == P(None, "data", None, "model")
    # mlp: d_ff 19200 divisible -> model on feature dim
    assert blk["ffn_w_up"] == P(None, "data", "model")
    assert specs["embed"] == P("model", "data")
