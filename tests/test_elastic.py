"""Elastic scaling: checkpoints restore across different mesh layouts
(the reshard-on-load path) and across config-compatible targets."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import load_checkpoint, save_checkpoint


def test_restore_onto_different_sharding():
    """Save with one sharding, restore with another (single device hosts
    both 'meshes' here; the device_put path is identical at scale)."""
    d = tempfile.mkdtemp()
    try:
        dev = np.asarray(jax.devices()[:1])
        mesh_a = Mesh(dev.reshape(1, 1), ("data", "model"))
        mesh_b = Mesh(dev.reshape(1,), ("all",))
        x = jax.device_put(
            jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            NamedSharding(mesh_a, P("data", "model")))
        save_checkpoint(d, 1, {"w": x})

        target = {"w": jax.ShapeDtypeStruct(
            (8, 8), jnp.float32,
            sharding=NamedSharding(mesh_b, P("all")))}
        out = load_checkpoint(d, 1, target)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(x))
        assert out["w"].sharding.spec == P("all")
    finally:
        shutil.rmtree(d, ignore_errors=True)


@pytest.mark.slow
def test_trainer_state_restores_into_fresh_trainer_different_batch():
    """Elastic DP resize: the same checkpoint drives a trainer whose
    dataset has a different global batch (the param/opt state is batch-
    agnostic; the deterministic data stream is re-derived per step)."""
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.data import SyntheticTokenDataset
    from repro.train import Trainer

    cfg = get_config("phi3-mini-3.8b", smoke=True)
    d = tempfile.mkdtemp()
    try:
        tcfg = TrainConfig(total_steps=4, checkpoint_every=2,
                           checkpoint_dir=d, async_checkpoint=False,
                           log_every=1)
        ds8 = SyntheticTokenDataset(cfg.vocab_size, 32, 8, seed=0)
        tr = Trainer(cfg, tcfg, ds8)
        tr.init_state()
        tr.run(4)

        ds4 = SyntheticTokenDataset(cfg.vocab_size, 32, 4, seed=0)
        tr2 = Trainer(cfg, tcfg, ds4)   # "smaller cluster"
        assert tr2.resume_or_init()
        assert tr2.step == 4
        log = tr2.run(6)
        assert log and np.isfinite(log[-1]["loss"])
    finally:
        shutil.rmtree(d, ignore_errors=True)
